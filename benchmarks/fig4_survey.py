"""Fig. 4 — AIMC/DIMC survey scatter: peak TOP/s/W vs TOP/s/mm^2 as
reported by the publications (the paper plots reported values; the
model validation against them is Fig. 5 / fig5_validation.py)."""

from __future__ import annotations

from repro.core import designs

from .common import timed


def run() -> None:
    def table() -> str:
        print(f"# {'design':26s} {'type':5s} {'node':>5s} {'bits':>6s} "
              f"{'TOPS/W':>8s} {'TOPS/mm2':>9s}  flags")
        best = {"aimc": None, "dimc": None}
        for d in designs.ALL_DESIGNS:
            m = d.macro
            flags = ("in-text" if d.in_text else
                     ("approx" if d.approx else ""))
            print(f"# {d.name:26s} {m.imc_type.value:5s} {m.tech_nm:4.0f}n "
                  f"{m.bi}b/{m.bw}b "
                  f"{d.reported_tops_w:8.1f} "
                  f"{d.reported_tops_mm2 if d.reported_tops_mm2 else 0:9.2f}"
                  f"  {flags}")
            key = m.imc_type.value
            if best[key] is None or d.reported_tops_w > best[key][1]:
                best[key] = (d.name, d.reported_tops_w)
        return (f"best_aimc={best['aimc'][0]}@{best['aimc'][1]:.0f} "
                f"best_dimc={best['dimc'][0]}@{best['dimc'][1]:.0f} "
                f"n={len(designs.ALL_DESIGNS)}")

    timed("fig4_survey", table)
