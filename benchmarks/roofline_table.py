"""Roofline table from the dry-run artifacts (brief §Roofline): three
terms per (arch x shape) on the single-pod mesh, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio."""

from __future__ import annotations

import json
from pathlib import Path

from repro import configs

from .common import timed

ART_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for arch in configs.ARCH_IDS:
        for shape in configs.SHAPES:
            p = ART_DIR / f"{arch}__{shape}__{mesh}.json"
            if p.exists():
                cells.append(json.loads(p.read_text()))
    return cells


def run() -> None:
    def table() -> str:
        cells = load_cells("single")
        ok = [c for c in cells if c.get("status") == "ok"]
        skipped = [c for c in cells if c.get("status") == "skipped"]
        failed = [c for c in cells if c.get("status") == "failed"]
        print(f"# {'arch':24s} {'shape':12s} {'compute':>9s} "
              f"{'mem(lo..hi)':>16s} {'coll':>9s} {'bottleneck':>10s} "
              f"{'useful':>6s} {'MFU':>5s}")
        for c in ok:
            r = c["roofline"]
            mlo = r.get("memory_s_lower", 0.0)
            print(f"# {c['arch']:24s} {c['shape']:12s} "
                  f"{r['compute_s']*1e3:8.1f}m "
                  f"{mlo*1e3:6.1f}..{r['memory_s']*1e3:7.1f}m "
                  f"{r['collective_s']*1e3:8.1f}m {r['bottleneck']:>10s} "
                  f"{r['useful_flops_ratio']:6.2f} {r['mfu']:5.2f}")
        for c in skipped:
            print(f"# {c['arch']:24s} {c['shape']:12s} SKIPPED "
                  f"({c['reason'][:60]})")
        # optimized-plan cells (EXPERIMENTS.md §Perf)
        n_opt = 0
        for p in sorted(ART_DIR.glob("*__single__*.json")):
            c = json.loads(p.read_text())
            if c.get("status") != "ok":
                continue
            r = c["roofline"]
            tag = p.stem.split("__single__")[1]
            print(f"# OPT {c['arch']:20s} {c['shape']:10s} [{tag}] "
                  f"mfu={r['mfu']:.3f} c={r['compute_s']:.2f}s "
                  f"coll={r['collective_s']:.2f}s")
            n_opt += 1
        return (f"ok={len(ok)} skipped={len(skipped)} "
                f"failed={len(failed)} optimized={n_opt}")

    timed("roofline_table", table)
