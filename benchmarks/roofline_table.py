"""Roofline table from the dry-run artifacts (brief §Roofline): three
terms per (arch x shape) on the single-pod mesh, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio.

Also prints an IMC-macro roofline (``imc_roofline_table``): for each
Table II design x tinyMLPerf network, compute cycles vs the
weight-write cycles embedded in the schedule and the outer-memory
traffic, from the batched DSE engine's optimal mappings — the macro
analogue of the pod compute/memory/collective split."""

from __future__ import annotations

import json
from pathlib import Path

from repro import configs
from repro.core import designs, dse, workloads

from .common import timed

ART_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for arch in configs.ARCH_IDS:
        for shape in configs.SHAPES:
            p = ART_DIR / f"{arch}__{shape}__{mesh}.json"
            if p.exists():
                cells.append(json.loads(p.read_text()))
    return cells


def run() -> None:
    def table() -> str:
        cells = load_cells("single")
        ok = [c for c in cells if c.get("status") == "ok"]
        skipped = [c for c in cells if c.get("status") == "skipped"]
        failed = [c for c in cells if c.get("status") == "failed"]
        print(f"# {'arch':24s} {'shape':12s} {'compute':>9s} "
              f"{'mem(lo..hi)':>16s} {'coll':>9s} {'bottleneck':>10s} "
              f"{'useful':>6s} {'MFU':>5s}")
        for c in ok:
            r = c["roofline"]
            mlo = r.get("memory_s_lower", 0.0)
            print(f"# {c['arch']:24s} {c['shape']:12s} "
                  f"{r['compute_s']*1e3:8.1f}m "
                  f"{mlo*1e3:6.1f}..{r['memory_s']*1e3:7.1f}m "
                  f"{r['collective_s']*1e3:8.1f}m {r['bottleneck']:>10s} "
                  f"{r['useful_flops_ratio']:6.2f} {r['mfu']:5.2f}")
        for c in skipped:
            print(f"# {c['arch']:24s} {c['shape']:12s} SKIPPED "
                  f"({c['reason'][:60]})")
        # optimized-plan cells (EXPERIMENTS.md §Perf)
        n_opt = 0
        for p in sorted(ART_DIR.glob("*__single__*.json")):
            c = json.loads(p.read_text())
            if c.get("status") != "ok":
                continue
            r = c["roofline"]
            tag = p.stem.split("__single__")[1]
            print(f"# OPT {c['arch']:20s} {c['shape']:10s} [{tag}] "
                  f"mfu={r['mfu']:.3f} c={r['compute_s']:.2f}s "
                  f"coll={r['collective_s']:.2f}s")
            n_opt += 1
        return (f"ok={len(ok)} skipped={len(skipped)} "
                f"failed={len(failed)} optimized={n_opt}")

    timed("roofline_table", table)

    def imc_table() -> str:
        """Macro-level roofline over the batched DSE's optimal mappings:
        ideal compute cycles at 100 % utilization vs scheduled cycles
        (the gap is under-utilization + weight rewrites), plus traffic
        per MAC — compute-bound vs movement-bound per (design, net)."""
        dse.cache_clear()
        macros = designs.table2_designs()
        print(f"# {'network':18s} {'design':24s} {'ideal-cyc':>10s} "
              f"{'sched-cyc':>10s} {'eff':>5s} {'bits/MAC':>9s} bound")
        n_compute = 0
        rows = 0
        for net_name, fn in workloads.TINYML_NETWORKS.items():
            layers = fn()
            for macro in macros:
                r = dse.map_network(net_name, layers, macro)
                ideal = sum(l.layer.macs for l in r.layers) \
                    / (macro.macs_per_cycle * macro.n_macros)
                eff = ideal / r.total_cycles
                bits_per_mac = sum(r.traffic_bits().values()) / r.total_macs
                bound = "compute" if eff > 0.5 else "movement"
                n_compute += bound == "compute"
                rows += 1
                print(f"# {net_name:18s} {macro.name:24s} {ideal:10.3g} "
                      f"{r.total_cycles:10.3g} {eff:5.2f} "
                      f"{bits_per_mac:9.2f} {bound}")
        return (f"pairs={rows} compute_bound={n_compute} "
                f"movement_bound={rows - n_compute}")

    timed("imc_roofline_table", imc_table)
