"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the artifacts.

Usage: PYTHONPATH=src python experiments/make_report.py > /tmp/tables.md
"""

import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "dryrun"
ARCHS = ["qwen1.5-0.5b", "glm4-9b", "gemma3-1b", "minicpm3-4b",
         "jamba-1.5-large-398b", "olmoe-1b-7b", "arctic-480b",
         "paligemma-3b", "musicgen-large", "rwkv6-7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_b(x):
    if x is None:
        return "-"
    return f"{x/2**30:.2f}"


def cell(arch, shape, mesh):
    p = ART / f"{arch}__{shape}__{mesh}.json"
    return json.loads(p.read_text()) if p.exists() else None


def dryrun_table():
    print("| arch | shape | mesh | chips | compile s | resident GiB/dev "
          "| collectives (top kinds) |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            for m in ("single", "multi"):
                c = cell(a, s, m)
                if c is None:
                    continue
                if c["status"] == "skipped":
                    if m == "single":
                        print(f"| {a} | {s} | both | - | - | - | "
                              f"SKIPPED: sub-quadratic rule |")
                    continue
                r = c["roofline"]
                colls = sorted(r["collectives"].items(),
                               key=lambda kv: -kv[1]["bytes"])[:2]
                ctxt = ", ".join(
                    f"{k} {v['bytes']/2**30:.1f}GiB/{int(v['count'])}x"
                    for k, v in colls) or "none"
                res = c["memory_analysis"].get("resident_bytes_per_device")
                print(f"| {a} | {s} | {m} | {c['chips']} "
                      f"| {c['compile_s']:.0f} | {fmt_b(res)} | {ctxt} |")


def roofline_table():
    print("| arch | shape | compute s | memory s (lo..hi) | collective s "
          "| bottleneck | useful | MFU lower-bound |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            c = cell(a, s, "single")
            if c is None or c["status"] != "ok":
                if c and c["status"] == "skipped":
                    print(f"| {a} | {s} | - | - | - | skipped "
                          f"(full attention) | - | - |")
                continue
            r = c["roofline"]
            print(f"| {a} | {s} | {r['compute_s']:.3f} "
                  f"| {r['memory_s_lower']:.3f}..{r['memory_s']:.1f} "
                  f"| {r['collective_s']:.3f} | {r['bottleneck']} "
                  f"| {r['useful_flops_ratio']:.2f} | {r['mfu']:.3f} |")


if __name__ == "__main__":
    print("## Dry-run table\n")
    dryrun_table()
    print("\n## Roofline table (single-pod)\n")
    roofline_table()
